"""Concurrent serve-plane bench: 64 simulated clients against one archive
behind a modelled network link (RemoteByteStore — real per-request latency,
shared-link wire time), sequential for-loop vs worker pool + coalescing.

What these rows watch across PRs:

  * ``serve/seq/clients=64`` — the pre-serve-plane shape: one thread
    handles the client stream in arrival order; every request's link
    round-trips and recompose serialize end to end.
  * ``serve/pool/clients=64/workers=8`` — the serve plane: per-client
    sessions run on 8 workers (round-trips of distinct requests overlap)
    and concurrent duplicate tightens coalesce into one fetch + one
    recompose fanned out to the waiters.  ``speedup`` is sequential wall
    over pooled wall and must hold >= 2x — the tentpole claim; the derived
    string also carries coalesce hits vs leader flights.
  * ``serve/tail/clients=64/workers=8`` — tail amplification under
    concurrency: us_per_call is the pooled p99 handle latency, derived
    ``tail`` = p99/p50.  Queueing convoys (a lost per-session lock, an
    accidental global serialization) show up here before they show in the
    mean.
  * ``serve/batched_tick/clients=64/workers=8`` — the same 64 requests
    submitted round-robin across (var, eps) groups (the mixed-tenant tick
    shape) through the full stack: pool + coalescer + a shared
    ``DecodeBatcher``, every group on the fused device-decode path.
    Distinct flights run on distinct workers and their fused decode
    flushes / device recomposes merge into vmapped dispatches per batching
    window.  ``dispatch_ratio`` (decode items per device dispatch, from
    BatcherStats) must hold >= 2 — the batching claim — with wall time
    still well under the sequential baseline (``speedup_vs_seq``).

Both modes run the SAME request schedule and per-client sticky sessions;
the workload mixes duplicate (var, eps) tightens across clients — the
multi-tenant dashboard shape coalescing exists for — with per-client
unique work.  Reconstruction results are asserted bit-identical between
the two modes before any row is emitted (the plane-count invariant: same
final fetched-plane counts => same bytes).
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np

from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.options import SessionOptions
from repro.serve import DecodeBatcher, ReconstructCoalescer, ServePlane
from repro.store import MemoryByteStore, RemoteByteStore, SegmentCache
from repro.store.container import StoreArchive, build_sharded_container

N_CLIENTS = 64
WORKERS = 8
LATENCY_S = 2e-4              # LAN round-trip per request (propagation)
BANDWIDTH_BPS = 400e6         # shared-link wire rate, FIFO
EPS_LADDER = (1e-3, 1e-6)
BATCH_WINDOW_MS = 30.0        # decode-batching window for the batched row


def _schedule(variables):
    """64 clients -> one (client, var, eps) request each, bursty: identical
    (var, eps) pairs arrive back-to-back — the dashboard-refresh shape
    (many tenants tightening the same hot variable at once) that
    cross-session coalescing exists for — while distinct pairs fill the
    other worker slots (the pool overlaps their round-trips)."""
    reqs = []
    for i in range(N_CLIENTS):
        var = variables[i % len(variables)]
        eps = EPS_LADDER[(i // len(variables)) % len(EPS_LADDER)]
        reqs.append((f"c{i:02d}", var, eps))
    reqs.sort(key=lambda r: (r[1], r[2]))
    return reqs


def _interleave(reqs, width=3):
    """Round-robin the schedule across (var, eps) groups, ``width`` requests
    per group per cycle: nearby requests hit DISTINCT reconstructions, so
    the worker pool runs several different flights at once — the
    mixed-tenant tick shape the decode batcher exists for (a fully bursty
    order leaves the batcher nothing to merge: the coalescer collapses the
    duplicates and its few leaders barely overlap).  ``width`` > 1 keeps a
    duplicate adjacent to its leader so coalescing still collapses most
    repeat work."""
    groups = {}
    for r in reqs:
        groups.setdefault((r[1], r[2]), []).append(r)
    out, queues = [], list(groups.values())
    while len(out) < len(reqs):
        for q in queues:
            out.extend(q[:width])
            del q[:width]
    return out


class _MiniServer:
    """The serve-plane stack minus the CLI: one StoreArchive over the modelled link
    model, a cross-session SegmentCache, sticky per-client sessions, and —
    in pooled mode — a ServePlane plus cross-session coalescer."""

    def __init__(self, manifest, payload, workers=None, coalesce=False,
                 decode_batcher=None):
        self.remote = RemoteByteStore(MemoryByteStore(payload),
                                      latency_s=LATENCY_S,
                                      bandwidth_bps=BANDWIDTH_BPS)
        self.cache = SegmentCache(max_bytes=256 << 20)
        self.archive = StoreArchive(manifest, self.remote,
                                    prefetch_workers=2, cache=self.cache)
        self.coalescer = ReconstructCoalescer() if coalesce else None
        self.decode_batcher = decode_batcher
        self.sessions = {}
        self._mu = threading.Lock()
        self.results = {}
        self.plane = None
        if workers is not None:
            self.plane = ServePlane(self.handle, workers=workers,
                                    queue_depth=4 * N_CLIENTS,
                                    session_key=lambda r: r[0],
                                    decode_batcher=decode_batcher)

    def handle(self, req):
        client, var, eps = req
        with self._mu:
            session = self.sessions.get(client)
            if session is None:
                session = self.archive.open(SessionOptions(
                    decode_batcher=self.decode_batcher))
                session.coalescer = self.coalescer
                self.sessions[client] = session
        data, achieved = session.reconstruct(var, eps)
        self.results[req] = data
        return achieved

    def close(self):
        if self.plane is not None:
            self.plane.shutdown(wait=True)
        self.archive.close()


def _quantiles(latencies_s):
    lat = np.sort(np.asarray(latencies_s))
    return (float(np.percentile(lat, 50)) * 1e3,
            float(np.percentile(lat, 99)) * 1e3)


def run():
    fields = ge_like_fields(n=1 << 15, seed=0)
    arch = refactor_variables(fields, method="hb")
    manifest, payloads = build_sharded_container(arch, shard_by="single")
    manifest = json.loads(json.dumps(manifest))
    payload = payloads[""]
    variables = sorted(fields)
    reqs = _schedule(variables)

    # untimed warmup: reader jit + codec dispatch, off the link model, so
    # the sequential row isn't charged for first-touch compilation.  A
    # fresh session per rung matches the clients' one-shot fetch shapes
    # (each timed client jumps straight to its eps from a cold state).
    warm = StoreArchive(manifest, MemoryByteStore(payload),
                        prefetch_workers=2)
    try:
        for eps in EPS_LADDER:
            s = warm.open()
            for v in variables:
                s.reconstruct(v, eps)
    finally:
        warm.close()

    # sequential baseline: one thread, arrival order
    seq = _MiniServer(manifest, payload)
    try:
        lat = []
        t0 = time.perf_counter()
        for req in reqs:
            r0 = time.perf_counter()
            seq.handle(req)
            lat.append(time.perf_counter() - r0)
        seq_wall = time.perf_counter() - t0
        seq_p50, seq_p99 = _quantiles(lat)
        seq_bytes = seq.remote.stats.bytes_moved
        seq_results = dict(seq.results)
    finally:
        seq.close()

    # pooled: same schedule through the serve plane, coalescing on
    pool = _MiniServer(manifest, payload, workers=WORKERS, coalesce=True)
    try:
        t0 = time.perf_counter()
        futures = [pool.plane.submit(req) for req in reqs]
        for fut in futures:
            fut.result()
        pool_wall = time.perf_counter() - t0
        pm = pool.plane.metrics()
        cm = pool.coalescer.metrics()
        pool_bytes = pool.remote.stats.bytes_moved
        for req in reqs:        # bit-identity: concurrency must not show
            np.testing.assert_array_equal(pool.results[req],
                                          seq_results[req])
    finally:
        pool.close()

    # batched tick: the interleaved schedule through pool + coalescer +
    # shared DecodeBatcher merging concurrent flights' device work.  One
    # untimed pass first compiles the vmapped batch graphs (batch sizes
    # are padded to powers of two, so the timed pass reuses them even when
    # bucket compositions differ).
    # every group rides the batcher here (not just the >= FUSED_MIN_COUNT
    # ones "auto" picks): a reader's small same-shape levels stack into one
    # vmapped dispatch alongside its neighbours' — the per-tick dispatch
    # collapse the row exists to measure
    from repro.kernels import ops
    bat_reqs = _interleave(reqs)
    prev_path = ops.set_decode_path("fused")
    try:
        for timed_pass in (False, True):
            bat = DecodeBatcher(window_ms=BATCH_WINDOW_MS)
            srv = _MiniServer(manifest, payload, workers=WORKERS,
                              coalesce=True, decode_batcher=bat)
            try:
                t0 = time.perf_counter()
                futures = [srv.plane.submit(req) for req in bat_reqs]
                for fut in futures:
                    fut.result()
                bat_wall = time.perf_counter() - t0
                bs = bat.stats.as_dict()
                for req in reqs:    # bit-identity: batching must not show
                    np.testing.assert_array_equal(srv.results[req],
                                                  seq_results[req])
            finally:
                srv.close()
    finally:
        ops.set_decode_path(prev_path)
    decode_ratio = (bs["decode_items"] / bs["decode_dispatches"]
                    if bs["decode_dispatches"] else 0.0)

    speedup = seq_wall / pool_wall
    p50, p99 = pm["latency_p50_ms"], pm["latency_p99_ms"]
    tail = p99 / p50 if p50 > 0 else float("inf")
    return [
        (f"serve/seq/clients={N_CLIENTS}", seq_wall * 1e6,
         f"p50={seq_p50:.1f}ms;p99={seq_p99:.1f}ms;"
         f"wire_bytes={seq_bytes}"),
        (f"serve/pool/clients={N_CLIENTS}/workers={WORKERS}",
         pool_wall * 1e6,
         f"speedup={speedup:.2f}x;p50={p50:.1f}ms;p99={p99:.1f}ms;"
         f"coalesce_hits={cm['hits_total']:.0f};"
         f"flights={cm['leaders_total']:.0f};"
         f"wire_bytes={pool_bytes}"),
        (f"serve/tail/clients={N_CLIENTS}/workers={WORKERS}", p99 * 1e3,
         f"tail={tail:.2f};p50={p50:.1f}ms;p99={p99:.1f}ms;"
         f"shed={pm['shed_total']:.0f}"),
        (f"serve/batched_tick/clients={N_CLIENTS}/workers={WORKERS}",
         bat_wall * 1e6,
         f"speedup_vs_seq={seq_wall / bat_wall:.2f}x;"
         f"dispatch_ratio={decode_ratio:.2f};"
         f"decode_items={bs['decode_items']:.0f};"
         f"decode_dispatches={bs['decode_dispatches']:.0f};"
         f"recompose_items={bs['recompose_items']:.0f};"
         f"recompose_dispatches={bs['recompose_dispatches']:.0f};"
         f"window_ms={BATCH_WINDOW_MS:g}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
