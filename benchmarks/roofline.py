"""Roofline table builder (EXPERIMENTS.md §Roofline).

Reads results/dryrun.json (written by repro.launch.dryrun) and derives per
(arch × shape × mesh):

  compute term    = HLO_dot_flops_per_dev / 197e12        [s]
  memory term     = analytic_HBM_bytes_per_dev / 819e9    [s]
                    (hlo output-bytes proxy reported alongside — it
                     overstates TPU traffic since fused elementwise chains
                     never hit HBM; see launch/analytic.py)
  collective term = HLO_collective_bytes_per_dev / 50e9   [s]

plus MODEL_FLOPS = 6·N(_active)·D, the useful-compute ratio, the dominant
term, and a one-line "what would move it" note.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict

from repro import configs
from repro.launch.analytic import attention_flops, hbm_bytes, model_flops
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import SHAPES

DEFAULT_PATH = "results/dryrun.json"


def _advice(dom: str, cfg, shape) -> str:
    if dom == "compute":
        if shape.kind == "train" and shape.seq_len >= 4096:
            return ("compute-bound: reduce causal-attention waste (block-"
                    "skip upper triangle) or drop remat on cheap layers")
        return "compute-bound: healthy; larger per-chip batch amortises"
    if dom == "memory":
        if shape.kind == "decode":
            return ("memory-bound (weights/cache streaming): quantise KV "
                    "cache or batch more sequences per chip")
        return "memory-bound: fuse/execute longer chains per HBM pass"
    return ("collective-bound: overlap collectives with compute, compress "
            "gradients (train/grad_compress.py), or reshard to cut "
            "resharding all-gathers")


def build_rows(results: Dict[str, Any]) -> list:
    rows = []
    for key, st in sorted(results.items()):
        if st.get("status") == "skipped":
            arch, shape_name, mesh_name = key.split("__")[:3]
            rows.append({"cell": key, "status": "skipped",
                         "reason": st["reason"]})
            continue
        if st.get("status") != "ok" or "hlo" not in st:
            rows.append({"cell": key, "status": st.get("status", "?"),
                         "error": str(st.get("error", ""))[:200]})
            continue
        arch, shape_name, mesh_name = key.split("__")[:3]
        cfg = configs.get(arch)
        shape = SHAPES[shape_name]
        ndev = st["n_devices"]
        flops_dev = st["hlo"]["dot_flops"]
        coll_dev = st["hlo"]["collective_bytes"]
        mem = hbm_bytes(cfg, shape, ndev)
        t_compute = flops_dev / PEAK_FLOPS_BF16
        t_memory = mem["total"] / HBM_BW
        t_coll = coll_dev / ICI_BW
        dom = max((("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll)), key=lambda kv: kv[1])[0]
        mf = model_flops(cfg, shape)
        af = attention_flops(cfg, shape)
        mf_dev = mf / ndev
        ratio = mf_dev / flops_dev if flops_dev else float("nan")
        bound = max(t_compute, t_memory, t_coll)
        frac = t_compute / bound if bound else 0.0
        rows.append({
            "cell": key, "status": "ok", "arch": arch, "shape": shape_name,
            "mesh": mesh_name, "n_devices": ndev,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom,
            "roofline_fraction": frac,
            "model_flops_total": mf, "attn_flops_total": af,
            "hlo_flops_dev": flops_dev,
            "useful_ratio": ratio,
            "mem_breakdown": mem,
            "collective_bytes_dev": coll_dev,
            "memory_bytes_dev": {"argument": st["memory"].get("argument_bytes"),
                                 "temp": st["memory"].get("temp_bytes"),
                                 "hlo_proxy": st["hlo"]["memory_bytes_proxy"],
                                 "analytic": mem["total"]},
            "advice": _advice(dom, cfg, shape),
        })
    return rows


def to_markdown(rows: list) -> str:
    out = ["| cell | compute s | memory s | collective s | dominant | "
           "useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['cell']} | — | — | — | {r.get('status')} "
                       f"| — | {r.get('reason', r.get('error', ''))[:60]} |")
            continue
        out.append(
            f"| {r['cell']} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e}"
            f" | {r['t_collective_s']:.3e} | {r['dominant']} |"
            f" {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return "\n".join(out)


def main(path: str = DEFAULT_PATH,
         out_json: str = "results/roofline.json") -> list:
    with open(path) as f:
        results = json.load(f)
    rows = build_rows(results)
    os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    print(to_markdown(rows))
    return rows


if __name__ == "__main__":
    main(*sys.argv[1:])
