"""Bench-regression gate: diff a fresh bench run against the committed
baseline and fail on perf drift.

    PYTHONPATH=src python -m benchmarks.run --only store --json /tmp/cur.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline BENCH_kernels.json --current /tmp/cur.json --tolerance 1.5

Only rows present in BOTH files are compared (a --only run produces a
subset), and rows faster than ``--min-us`` in both are skipped — they sit
inside scheduler noise.  The check is two-sided by default:

  * REGRESSION      current > baseline * tolerance  -> exit 1.  The PR made
                    a tracked path slower than runner noise can explain.
  * STALE-BASELINE  current < baseline / tolerance  -> exit 1 (disable with
                    --one-sided).  The committed baseline no longer
                    describes the code — an artificially inflated (or
                    simply outdated) entry would mask future regressions up
                    to its inflation factor, so it must be re-recorded
                    (run ``benchmarks.run`` without --only and commit the
                    refreshed BENCH_kernels.json).

The tolerance absorbs CI-runner noise; 1.5x is loose enough for shared
runners on µs-scale rows, tight enough to catch an accidental O(n) -> O(n²).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

OK = "ok"
REGRESSION = "REGRESSION"
STALE = "STALE-BASELINE"
SKIPPED = "skip (noise)"


def compare(baseline: Dict[str, dict], current: Dict[str, dict],
            tolerance: float = 1.5, min_us: float = 50.0,
            two_sided: bool = True,
            prefixes: Optional[List[str]] = None
            ) -> Tuple[List[Tuple[str, float, float, float, str]], List[str]]:
    """Returns (table rows ``(name, base_us, cur_us, ratio, status)``,
    failing row names)."""
    if tolerance <= 1.0:
        raise ValueError(f"tolerance must be > 1.0, got {tolerance}")
    rows: List[Tuple[str, float, float, float, str]] = []
    failures: List[str] = []
    for name in sorted(set(baseline) & set(current)):
        if prefixes and not any(name.startswith(p) for p in prefixes):
            continue
        base = float(baseline[name]["us_per_call"])
        cur = float(current[name]["us_per_call"])
        ratio = cur / base if base > 0 else (1.0 if cur == 0 else float("inf"))
        if base < min_us and cur < min_us:
            status = SKIPPED
        elif ratio > tolerance:
            status = REGRESSION
        elif two_sided and ratio < 1.0 / tolerance:
            status = STALE
        else:
            status = OK
        if status in (REGRESSION, STALE):
            failures.append(name)
        rows.append((name, base, cur, ratio, status))
    return rows, failures


def format_table(rows) -> str:
    name_w = max([len(r[0]) for r in rows] + [len("row")])
    lines = [f"{'row':<{name_w}}  {'baseline_us':>12}  {'current_us':>12}  "
             f"{'ratio':>7}  status",
             "-" * (name_w + 48)]
    for name, base, cur, ratio, status in rows:
        lines.append(f"{name:<{name_w}}  {base:>12.1f}  {cur:>12.1f}  "
                     f"{ratio:>6.2f}x  {status}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if tracked us_per_call rows drifted beyond the "
                    "tolerance")
    ap.add_argument("--baseline", default="BENCH_kernels.json")
    ap.add_argument("--current", required=True,
                    help="JSON written by `benchmarks.run --json PATH`")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="allowed ratio either way (default 1.5x, absorbs "
                         "runner noise)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip rows faster than this in both runs")
    ap.add_argument("--one-sided", action="store_true",
                    help="only fail on regressions, not on stale/inflated "
                         "baseline entries")
    ap.add_argument("--prefix", action="append", default=None,
                    help="only compare rows starting with this (repeatable)")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.current) as fh:
        current = json.load(fh)
    rows, failures = compare(baseline, current, tolerance=args.tolerance,
                             min_us=args.min_us,
                             two_sided=not args.one_sided,
                             prefixes=args.prefix)
    if not rows:
        print("bench gate: no overlapping rows between baseline and current "
              "— nothing was checked", file=sys.stderr)
        return 1
    print(format_table(rows))
    checked = sum(r[4] != SKIPPED for r in rows)
    if failures:
        print(f"\nbench gate: FAILED — {len(failures)} of {checked} tracked "
              f"rows drifted beyond {args.tolerance}x: "
              f"{', '.join(failures)}", file=sys.stderr)
        return 1
    print(f"\nbench gate: ok — {checked} rows within {args.tolerance}x "
          f"({len(rows) - checked} below the {args.min_us}us noise floor)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
