"""Beyond-paper table: the paper's technique inside the training stack.

(a) progressive checkpoint restore bytes vs tolerance (the paper's
    rate-precision trade applied to model state), and
(b) gradient all-reduce payload under bitplane compression vs f32.
"""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from benchmarks.common import timed
from repro import configs
from repro.models import transformer as T
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.grad_compress import payload_bytes


def run():
    rows = []
    cfg = configs.get_reduced("internlm2-1.8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    with tempfile.TemporaryDirectory() as d:
        dt_save, rep = timed(save_checkpoint, d, params, 0)
        rows.append(("train_integration/ckpt_save", dt_save * 1e6,
                     f"archive_bytes={rep['bytes']}"))
        full = None
        for tau in (0.0, 1e-6, 1e-3, 1e-1):
            dt, (_, r) = timed(restore_checkpoint, d, tau)
            if full is None:
                full = r.bytes_moved
            rows.append((f"train_integration/ckpt_restore/tau={tau:.0e}",
                         dt * 1e6,
                         f"bytes={r.bytes_moved};frac_of_full="
                         f"{r.bytes_moved / full:.3f}"))

    grads = jax.tree.map(lambda p: np.zeros(p.shape, np.float32), params)
    f32_bytes = sum(g.size * 4 for g in jax.tree.leaves(grads))
    for k in (16, 8, 4):
        b = payload_bytes(grads, k)
        rows.append((f"train_integration/grad_allreduce_payload/k={k}", 0.0,
                     f"bytes={b};vs_f32={b / f32_bytes:.3f}"))
    return rows
