"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Tuple

import numpy as np


def timed(fn: Callable, *args, repeat: int = 1, **kw) -> Tuple[float, object]:
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return dt, out


def actual_qoi_error(expr, orig_fields, recon_fields) -> float:
    truth = np.asarray(expr.value({k: np.asarray(v)
                                   for k, v in orig_fields.items()}))
    approx = np.asarray(expr.value(recon_fields))
    return float(np.abs(truth - approx).max())


def qoi_range(expr, fields) -> float:
    v = np.asarray(expr.value({k: np.asarray(x) for k, x in fields.items()}))
    r = float(v.max() - v.min())
    return r if r > 0 else 1.0
