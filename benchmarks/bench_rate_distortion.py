"""Paper Figs 2, 7, 8: rate-distortion of the three progressive families.

Fig 2: primary-data progressive requests ε'_i = 0.1·2^-i — bitrate per
method (PSZ3 shows snapshot redundancy, PSZ3-delta stair-cases, PMGARD-HB
is ~linear in log-ε).
Figs 7/8: single requested QoI error per session (VTOT on GE-like; molar
product on S3D-like) — retrieved bitrate per method.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed
from repro.core import ge
from repro.core.qoi import Prod, Var
from repro.core.refactor import refactor_variables
from repro.core.retrieval import QoIRequest, retrieve_qoi_controlled
from repro.data.synthetic import ge_like_fields, s3d_like_fields, smooth_field

METHODS = ("psz3", "psz3_delta", "hb")


def _progressive_pd_requests(fields, method):
    """Fig 2: request primary-data bounds directly on one variable."""
    arch = refactor_variables({"P": fields["P"]}, method=method,
                              mask_zero_velocity=False)
    session = arch.open()
    rng = arch.ranges["P"]
    out = []
    for i in range(1, 16, 2):
        eps = 0.1 * 2.0 ** -i * rng
        data, ach = session.reconstruct("P", eps)
        err = np.abs(data - fields["P"]).max()
        assert err <= ach * (1 + 1e-9), (method, i, err, ach)
        out.append((i, session.bitrate(["P"])))
    return out


def run():
    rows = []
    fields = ge_like_fields(n=1 << 15, seed=0)

    # Fig 2: progressive primary-data ladder
    for method in METHODS:
        dt, curve = timed(_progressive_pd_requests, fields, method)
        final_rate = curve[-1][1]
        mid_rate = curve[len(curve) // 2][1]
        rows.append((f"rate_distortion/fig2/{method}", dt * 1e6,
                     f"bitrate@mid={mid_rate:.2f};bitrate@tight={final_rate:.2f}"))

    # `ip` vs `hb`: wire bytes at EQUAL certified primary-data bound on a
    # smooth multi-octave field — the regime the interpolation predictor
    # targets.  These rows ride the CI bench gate (--prefix
    # rate_distortion/ip_vs_hb) and tests/test_ci_config.py pins the
    # committed baseline's mid-bitrate ratio <= 1, so a predictor change
    # that loses the byte win fails the build.
    smooth = smooth_field((257,), seed=5, lo=-3.0, hi=9.0)
    rng_s = float(smooth.max() - smooth.min())
    archs = {m: refactor_variables({"S": smooth}, method=m,
                                   mask_zero_velocity=False)
             for m in ("ip", "hb")}
    for rel in (1e-3, 1e-5, 1e-7):
        eps = rel * rng_s
        nbytes, dt_total = {}, 0.0
        for m, arch in archs.items():
            session = arch.open()
            dt, (data, ach) = timed(session.reconstruct, "S", eps)
            err = np.abs(data - smooth).max()
            assert err <= ach * (1 + 1e-9) and ach <= eps, (m, rel, err, ach)
            nbytes[m] = session.bytes_retrieved
            dt_total += dt
        rows.append((f"rate_distortion/ip_vs_hb/eps={rel:.0e}",
                     dt_total * 1e6,
                     f"ip_bytes={nbytes['ip']};hb_bytes={nbytes['hb']};"
                     f"ratio={nbytes['ip'] / nbytes['hb']:.3f}"))

    # Fig 7: single-request QoI (VTOT) per method
    for method in METHODS:
        arch = refactor_variables(
            {k: fields[k] for k in ("Vx", "Vy", "Vz")}, method=method)
        for tau in (1e-2, 1e-4, 1e-6):
            session = arch.open()
            dt, res = timed(retrieve_qoi_controlled, session,
                            [QoIRequest("VTOT", ge.v_total(), tau)])
            rows.append((f"rate_distortion/fig7/{method}/tau={tau:.0e}",
                         dt * 1e6,
                         f"bitrate={res.bitrate:.3f};conv={res.converged}"))

    # Fig 8: S3D molar product per method
    s3d = s3d_like_fields(shape=(33, 17, 17))
    sub = {k: s3d[k] for k in ("x1", "x3")}
    for method in METHODS:
        arch = refactor_variables(sub, method=method,
                                  mask_zero_velocity=False)
        for tau in (1e-3, 1e-5):
            session = arch.open()
            dt, res = timed(retrieve_qoi_controlled, session,
                            [QoIRequest("x1x3", Prod(Var("x1"), Var("x3")),
                                        tau)])
            rows.append((f"rate_distortion/fig8/{method}/tau={tau:.0e}",
                         dt * 1e6,
                         f"bitrate={res.bitrate:.3f};conv={res.converged}"))
    return rows
