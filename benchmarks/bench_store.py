"""Store subsystem micro-benches: container round-trip throughput, segment
fetch latency (cold demand vs warm prefetched), HTTP ranged-GET transport
over loopback (validating the RemoteByteStore link model against a real
socket), cross-session cache hit economics, live-archive append throughput
/ follow-mode latency / delta wire economics, and crc32c hashing rate —
the transport-path numbers tracked across PRs in BENCH_kernels.json."""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import timed
from repro.core.refactor import refactor_variables
from repro.data.synthetic import ge_like_fields
from repro.options import OpenOptions
from repro.store import (HTTPByteStore, SegmentCache, crc32c, open_archive,
                         save_archive)
from repro.store.httpd import StoreHTTPServer
from repro.store.writer import ArchiveWriter


def run():
    rows = []
    fields = ge_like_fields(n=1 << 16, seed=0)
    vel = {k: fields[k] for k in ("Vx", "Vy", "Vz")}
    arch = refactor_variables(vel, method="hb")
    fd, path = tempfile.mkstemp(suffix=".prs")
    os.close(fd)
    try:
        dt_save, nbytes = timed(save_archive, arch, path)
        rows.append(("store/save_archive/n=65536x3", dt_save * 1e6,
                     f"bytes={nbytes};"
                     f"MBps={nbytes / dt_save / 1e6:.0f}"))

        # best-of-3: a single manifest-parse+mmap is ~ms-scale and jitters
        # enough to trip the CI bench gate on shared runners
        dt_open = None
        for _ in range(3):
            dt, sa = timed(open_archive, path)
            if dt_open is None or dt < dt_open:
                dt_open = dt
            sa.close()
        sa = open_archive(path)
        nseg = len(sa.fetcher.index)
        rows.append(("store/open_archive", dt_open * 1e6,
                     f"segments={nseg}"))

        # cold full-archive verified read-through (mmap + crc + no decode)
        t0 = time.perf_counter()
        total = 0
        for key in sa.fetcher.index:
            total += len(sa.fetcher.fetch(key))
        dt_cold = time.perf_counter() - t0
        rows.append(("store/fetch_all_verified", dt_cold * 1e6,
                     f"bytes={total};MBps={total / dt_cold / 1e6:.0f}"))
        sa.close()

        # demand vs prefetched single-segment latency (file store, no link)
        sa = open_archive(path, OpenOptions(prefetch_workers=2))
        keys = sorted(sa.fetcher.index, key=lambda k: -sa.fetcher.index[k].size)
        demand = min(timed(sa.fetcher.fetch, keys[0])[0] for _ in range(5))
        sa.fetcher.prefetch([keys[1]])
        sa.fetcher.drain()
        warm, _ = timed(sa.fetcher.fetch, keys[1])
        rows.append(("store/fetch_latency_demand", demand * 1e6, "cold"))
        rows.append(("store/fetch_latency_prefetched", warm * 1e6,
                     f"speedup={demand / max(warm, 1e-9):.1f}"))
        # prefetch hit rate over a session that pulls everything through hints
        session = sa.open()
        for eps in (1e-2, 1e-4, 1e-6):
            for v in vel:
                session.prefetch(v, eps)
                session.reconstruct(v, eps)
        st = sa.fetcher.stats
        rows.append(("store/session_hit_rate", st.demand_wait_s * 1e6,
                     f"hit_rate={st.hit_rate:.2f};"
                     f"predicted={st.prefetch_hits};"
                     f"demand={st.demand_fetches}"))
        sa.close()

        # -- HTTP over loopback: a real socket under the same session shape.
        # Coalesced ranged GETs vs per-segment reads, and the cross-session
        # cache collapsing the second session's store traffic.
        with StoreHTTPServer(path) as srv:
            hs = HTTPByteStore(srv.url)
            cache = SegmentCache()
            with open_archive(hs, OpenOptions(prefetch_workers=2,
                                              cache=cache)) as ha:
                t0 = time.perf_counter()
                s1 = ha.open()
                for eps in (1e-2, 1e-4, 1e-6):
                    for v in vel:
                        s1.prefetch(v, eps)
                        s1.reconstruct(v, eps)
                dt_cold = time.perf_counter() - t0
                reads_1 = ha.fetcher.stats.store_reads
                rows.append((
                    "store/http_session_cold", dt_cold * 1e6,
                    f"requests={hs.stats.requests};"
                    f"store_reads={reads_1};"
                    f"coalesced={hs.stats.coalesced_ranges};"
                    f"retries={hs.stats.retries}"))
                # min-of-3: the warm pass is pure decode/recompose compute
                # and a one-shot timing swings ~2x with box contention
                dt_warm = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    s2 = ha.open()
                    for v in vel:
                        s2.reconstruct(v, 1e-6)
                    dt_warm = min(dt_warm, time.perf_counter() - t0)
                reads_2 = ha.fetcher.stats.store_reads - reads_1
                rows.append((
                    "store/http_session_cached", dt_warm * 1e6,
                    f"store_reads={reads_2};"
                    f"cache_hits={ha.fetcher.stats.cache_hits};"
                    f"speedup={dt_cold / max(dt_warm, 1e-9):.1f}"))
    finally:
        if os.path.exists(path):
            os.unlink(path)

    # -- live v4 archive: append throughput, follow-mode latency, and the
    # delta-vs-keyframe wire economics that justify the journal
    tmpdir = tempfile.mkdtemp(prefix="bench_live_")
    try:
        live = os.path.join(tmpdir, "arch")
        n_t, base = 8, fields["Vx"]
        frames = [np.asarray(base * (1.0 + 0.02 * k), dtype=base.dtype)
                  for k in range(n_t + 1)]
        w = ArchiveWriter.create(live, keyframe_interval=4)
        t0 = time.perf_counter()
        for f in frames[:n_t]:
            w.append({"T": f}, eps=1e-3)
        dt_append = time.perf_counter() - t0
        raw = base.nbytes * n_t
        rows.append(("store/append_throughput", dt_append / n_t * 1e6,
                     f"timesteps={n_t};"
                     f"raw_MBps={raw / dt_append / 1e6:.0f}"))

        # delta vs independent wire bytes, straight from the live manifest
        sa = open_archive(live)
        var = sa.variables["T"]
        key_b = [var.handle(t).nbytes for t in range(n_t)
                 if var.handle(t).keyframe]
        del_b = [var.handle(t).nbytes for t in range(n_t)
                 if not var.handle(t).keyframe]
        mean_k = sum(key_b) / len(key_b)
        mean_d = sum(del_b) / len(del_b)
        rows.append(("store/append_delta_bytes", mean_d,
                     f"keyframe_bytes={mean_k:.0f};"
                     f"ratio={mean_d / mean_k:.2f}"))

        # follow-mode latency: one new append -> poll (journal re-read +
        # replay) + chained delta decode of the new timestep
        st = sa.open()
        stream = st.follow("T")
        for t in stream.poll():
            stream.read(t)
        w.append({"T": frames[n_t]}, eps=1e-3)
        t0 = time.perf_counter()
        (new_t,) = stream.poll()
        stream.read(new_t)
        dt_follow = time.perf_counter() - t0
        rows.append(("store/follow_latency", dt_follow * 1e6,
                     f"t={new_t};"
                     f"bytes={var.handle(new_t).nbytes}"))
        sa.close()
        w.close()
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)

    buf = np.random.default_rng(0).integers(
        0, 256, 1 << 22, dtype=np.uint8).tobytes()
    dt_crc = min(timed(crc32c, buf)[0] for _ in range(3))
    rows.append(("store/crc32c/4MiB", dt_crc * 1e6,
                 f"MBps={len(buf) / dt_crc / 1e6:.0f}"))
    return rows
